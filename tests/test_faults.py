"""Fault tolerance (repro.faults + the transactional/durable service).

Four properties, each pinned differentially:

1. **Rollback**: an ingest aborted at *any* injected site leaves the
   service bit-for-bit the state it had before the call
   (``state_digest`` equality), across in-order, permuted, and
   canopy-re-split (retraction) schedules, and leaves zero trace in the
   downstream fixpoint once the stream continues.
2. **Durability**: a worker ``os._exit``-killed at any site recovers
   from checkpoint + WAL tail to the uninterrupted run's digest.
3. **Isolation**: a poisoned request quarantines alone; innocent
   co-batched tickets commit (bisection).
4. **Degradation**: transient faults retry with capped backoff; id
   assignment commits only on success.
"""

from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import faultcorpus
from repro import faults, obs
from repro.faults import CRASH_EXIT_CODE, FaultPlan, InjectedFault, PoisonedRequest
from repro.stream import ResolveService
from repro.stream.digest import state_digest
from repro.stream.serving import AdmissionError, ServingConfig, ServingFrontend
from repro.stream.wal import WriteAheadLog

REPO = Path(__file__).resolve().parent.parent

SMP_SITES = ("lsh", "replay", "cover_splice", "rounds", "commit")
MMP_SITES = ("lsh", "replay", "cover_splice", "grounding_splice", "rounds",
             "commit")


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    yield
    faults.clear()


@pytest.fixture(scope="module")
def batches():
    return faultcorpus.batches()


@pytest.fixture(scope="module")
def base_digest_smp():
    return state_digest(faultcorpus.run_uninterrupted("smp"))


@pytest.fixture(scope="module")
def base_digest_mmp():
    return state_digest(faultcorpus.run_uninterrupted("mmp"))


def _ingest(svc, b):
    return svc.ingest(b.names, b.edges, ids=b.ids)


# ---------------------------------------------------------------------------
# 1. Transactional rollback: aborted ingest == never submitted
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "scheme,site",
    [("smp", s) for s in SMP_SITES] + [("mmp", s) for s in MMP_SITES],
)
def test_rollback_differential(scheme, site, batches, base_digest_smp,
                               base_digest_mmp):
    """Abort batch 3 at every site; state must equal pre-submit exactly,
    and finishing the stream must reach the clean run's digest."""
    svc = ResolveService(scheme=scheme)
    _ingest(svc, batches[0])
    _ingest(svc, batches[1])
    before = state_digest(svc)
    with faults.injected(FaultPlan.fail_once(site)):
        with pytest.raises(InjectedFault):
            _ingest(svc, batches[2])
    assert state_digest(svc) == before, f"rollback left residue at {site}"
    _ingest(svc, batches[2])
    _ingest(svc, batches[3])
    base = base_digest_smp if scheme == "smp" else base_digest_mmp
    assert state_digest(svc) == base, f"abort at {site} perturbed the stream"


@pytest.mark.parametrize("order", [[1, 0, 3, 2], [3, 2, 1, 0]])
def test_rollback_differential_permuted_schedule(order, batches):
    """Same differential under out-of-order arrival (id holes)."""
    clean = ResolveService(scheme="smp")
    for i in order:
        _ingest(clean, batches[i])
    svc = ResolveService(scheme="smp")
    for k, i in enumerate(order):
        if k == 2:  # abort mid-schedule, then re-run the same batch
            before = state_digest(svc)
            with faults.injected(FaultPlan.fail_once("rounds")):
                with pytest.raises(InjectedFault):
                    _ingest(svc, batches[i])
            assert state_digest(svc) == before
        _ingest(svc, batches[i])
    assert state_digest(svc) == state_digest(clean)


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
def test_rollback_differential_retraction_schedule(scheme):
    """Abort the canopy-re-split ingest (candidate retraction + match
    invalidation) at the engine site; rollback must restore the
    pre-split cover, grounding, and message pool exactly."""
    names, first, second = (faultcorpus.RESPLIT_NAMES,
                            faultcorpus.RESPLIT_FIRST,
                            faultcorpus.RESPLIT_SECOND)
    clean = ResolveService(scheme=scheme)
    clean.ingest([names[i] for i in first], ids=first)
    clean.ingest([names[i] for i in second], ids=second)
    assert clean.reports[-1].n_invalidated > 0  # the retraction fired

    svc = ResolveService(scheme=scheme)
    svc.ingest([names[i] for i in first], ids=first)
    before = state_digest(svc)
    for site in ("cover_splice", "rounds", "commit"):
        with faults.injected(FaultPlan.fail_once(site)):
            with pytest.raises(InjectedFault):
                svc.ingest([names[i] for i in second], ids=second)
        assert state_digest(svc) == before, f"retraction rollback: {site}"
    svc.ingest([names[i] for i in second], ids=second)
    assert state_digest(svc) == state_digest(clean)


def test_rollback_on_natural_error(batches):
    """Not just injected faults: a real validation error (duplicate id)
    mid-ingest also rolls back to pre-submit state."""
    svc = ResolveService(scheme="smp")
    _ingest(svc, batches[0])
    before = state_digest(svc)
    with pytest.raises(ValueError):
        _ingest(svc, batches[0])  # same ids again
    assert state_digest(svc) == before
    _ingest(svc, batches[1])  # stream continues cleanly


def test_wal_append_fault_rolls_back_and_recovers(tmp_path, batches):
    """A fault at the WAL append site aborts before any state mutates;
    the consumed sequence number is a harmless gap on replay."""
    svc = ResolveService(scheme="smp", durability_dir=str(tmp_path))
    _ingest(svc, batches[0])
    before = state_digest(svc)
    with faults.injected(FaultPlan.fail_once("wal.append")):
        with pytest.raises(InjectedFault):
            _ingest(svc, batches[1])
    assert state_digest(svc) == before
    _ingest(svc, batches[1])
    svc.close()
    rec = ResolveService.recover(str(tmp_path), scheme="smp")
    assert state_digest(rec) == state_digest(svc)
    rec.close()


# ---------------------------------------------------------------------------
# 2. Durability: WAL + checkpoint recovery
# ---------------------------------------------------------------------------


def test_wal_roundtrip_and_abort_markers(tmp_path):
    wal = WriteAheadLog(tmp_path)
    wal.append(1, ["a"], None, [0])
    wal.append(2, ["b"], np.array([[0, 1]], dtype=np.int64), [1])
    wal.append_abort(2)
    wal.append(3, ["c"], None, [2])
    wal.close()
    records, aborted = WriteAheadLog.scan(tmp_path)
    assert [r.seq for r in records] == [1, 2, 3]
    assert aborted == {2}
    assert records[1].names == ["b"]
    assert records[1].edges.tolist() == [[0, 1]]


def test_wal_torn_tail_truncated(tmp_path):
    wal = WriteAheadLog(tmp_path)
    wal.append(1, ["a"], None, [0])
    wal.append(2, ["b"], None, [1])
    wal.close()
    seg = sorted(tmp_path.glob("wal-*.log"))[-1]
    good = seg.stat().st_size
    with open(seg, "ab") as f:  # a crash mid-append: garbage tail
        f.write(b"\xff" * 11)
    records, _ = WriteAheadLog.scan(tmp_path)
    assert [r.seq for r in records] == [1, 2]
    assert seg.stat().st_size == good  # scan repaired the tail
    wal = WriteAheadLog(tmp_path)  # and the log is appendable again
    wal.append(3, ["c"], None, [2])
    wal.close()
    records, _ = WriteAheadLog.scan(tmp_path)
    assert [r.seq for r in records] == [1, 2, 3]


def test_wal_rotate_gc(tmp_path):
    wal = WriteAheadLog(tmp_path)
    wal.append(1, ["a"], None, [0])
    wal.append(2, ["b"], None, [1])
    wal.rotate(3)
    wal.append(3, ["c"], None, [2])
    assert wal.gc(2) == 1  # the seq 1-2 segment is checkpoint-covered
    wal.close()
    records, _ = WriteAheadLog.scan(tmp_path)
    assert [r.seq for r in records] == [3]


def test_checkpoint_cadence_and_recovery(tmp_path, batches):
    svc = ResolveService(
        scheme="mmp", durability_dir=str(tmp_path), checkpoint_every=2
    )
    for b in batches:
        _ingest(svc, b)
    want = state_digest(svc)
    svc.close()
    assert svc._ckpt.all_steps() == [2, 4]
    rec = ResolveService.recover(str(tmp_path), scheme="mmp",
                                 checkpoint_every=2)
    assert state_digest(rec) == want
    assert rec._seq == 4  # fresh ingests resume past the recovered tail
    rec.close()


def test_wal_only_recovery(tmp_path, batches, base_digest_smp):
    svc = ResolveService(scheme="smp", durability_dir=str(tmp_path))
    for b in batches:
        _ingest(svc, b)
    svc.close()
    rec = ResolveService.recover(str(tmp_path), scheme="smp")
    assert state_digest(rec) == base_digest_smp
    rec.close()


# (site, hit) legs: the per-batch ingest sites fire every batch, so hit
# 3 kills the worker mid-batch-3 (after two clean commits and the first
# checkpoint); the durability-path sites fire once per checkpoint —
# hit 1 lands inside the first checkpoint's rename/rotation window
# (checkpoint incomplete / WAL not yet rotated), hit 2 inside the
# second, after the final batch committed.
_CKPT_SITES = ("ckpt.rename", "wal.rotate")
CRASH_MATRIX = [
    (site, hit)
    for site in faults.SITES
    for hit in ((1, 2) if site in _CKPT_SITES else (3,))
]


@pytest.mark.parametrize(
    "site,hit", CRASH_MATRIX, ids=[f"{s}-hit{h}" for s, h in CRASH_MATRIX]
)
def test_crash_recovery_matrix(site, hit, tmp_path, batches, base_digest_mmp):
    """Kill the worker (os._exit, no unwinding) at every fault site —
    mid-batch between the WAL append and the commit, mid-checkpoint
    before the tmp-dir rename, and at the WAL rotation boundary — then
    recover and finish the stream: the digest must equal the
    uninterrupted run's, bit for bit."""
    dur = tmp_path / "dur"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "crash_worker.py"),
         str(dur), "mmp", site, "2", str(hit)],
        cwd=REPO,
        capture_output=True,
        timeout=600,
    )
    assert proc.returncode == CRASH_EXIT_CODE, (
        f"worker did not crash at {site}: rc={proc.returncode}\n"
        f"{proc.stderr.decode()[-2000:]}"
    )
    rec = ResolveService.recover(str(dur), scheme="mmp", checkpoint_every=2)
    # seq k holds batch k-1; a crash before the append leaves a seq gap
    # the resumed producer simply re-submits
    for b in batches[rec._seq:]:
        _ingest(rec, b)
    assert state_digest(rec) == base_digest_mmp, f"crash at {site} diverged"
    rec.close()


# ---------------------------------------------------------------------------
# 3. Poison-batch isolation (serving front-end bisection)
# ---------------------------------------------------------------------------


def test_poison_bisection_settles_innocents(batches):
    """Four coalesced requests, one poisoned: bisection must land the
    poison alone in quarantine while every innocent ticket commits."""
    obs.reset()
    b = batches[0]
    bad = b.names[0]
    svc = ResolveService(scheme="smp")
    cfg = ServingConfig(max_batch=64, max_delay_ms=100.0, max_retries=1,
                        backoff_base_ms=0.1, backoff_max_ms=0.5)
    fe = ServingFrontend(svc, cfg, start=False)
    tickets = [fe.submit([nm]) for nm in b.names[:4]]
    faults.install(FaultPlan(poison_names={bad}, poison_site="rounds"))
    fe.start()
    assert fe.drain(timeout=60.0)
    with pytest.raises(PoisonedRequest):
        tickets[0].wait(timeout=10.0)
    reports = [t.wait(timeout=10.0) for t in tickets[1:]]
    assert all(r.new_matches >= 0 for r in reports)
    # the innocents' names are resolvable; ids were committed to tickets
    for t in tickets[1:]:
        assert t.ids is not None and len(t.ids) == 1
        assert fe.resolve(t.ids[0]) is not None
    assert tickets[0].ids is None  # the quarantined ticket never got ids
    reg = obs.get_registry()
    assert reg.value("serve.quarantined") == 1
    assert reg.value("serve.errors") == 1  # once per quarantine, not per try
    assert reg.value("serve.faults.bisections") >= 1
    faults.clear()
    fe.close()


# ---------------------------------------------------------------------------
# 4. Retry/backoff degradation + id-assignment regression
# ---------------------------------------------------------------------------


def test_transient_fault_retries_to_success(batches):
    """A fault that clears after two hits: the flush retries through it
    and every ticket commits — no bisection, no quarantine."""
    obs.reset()
    b = batches[0]
    svc = ResolveService(scheme="smp")
    cfg = ServingConfig(max_delay_ms=50.0, max_retries=3,
                        backoff_base_ms=0.1, backoff_max_ms=0.5)
    fe = ServingFrontend(svc, cfg, start=False)
    tickets = [fe.submit([nm]) for nm in b.names[:3]]
    faults.install(FaultPlan(site_hits={"rounds": {1, 2}}))
    fe.start()
    assert fe.drain(timeout=60.0)
    for t in tickets:
        t.wait(timeout=10.0)
    reg = obs.get_registry()
    assert reg.value("serve.retries") == 2
    assert reg.value("serve.faults.flush") == 2
    assert reg.value("serve.quarantined") == 0
    assert reg.value("serve.errors") == 0
    faults.clear()
    fe.close()


def test_backoff_is_capped_under_sustained_faults(batches):
    """Every retry's backoff obeys min(max, base * 2**k) — the cap must
    bind — and exhaustion quarantines with the original error."""
    obs.reset()
    b = batches[0]
    svc = ResolveService(scheme="smp")
    cfg = ServingConfig(max_delay_ms=10.0, max_retries=5,
                        backoff_base_ms=1.0, backoff_max_ms=3.0)
    fe = ServingFrontend(svc, cfg, start=False)
    ticket = fe.submit([b.names[0]])
    faults.install(FaultPlan(site_hits={"rounds": frozenset(range(1, 50))}))
    fe.start()
    assert fe.drain(timeout=60.0)
    with pytest.raises(InjectedFault):
        ticket.wait(timeout=10.0)
    summ = obs.get_registry().histogram("serve.backoff_ms").summary()
    assert summ["count"] == 5
    assert summ["max"] <= 3.0  # the cap binds (uncapped would reach 16)
    assert obs.get_registry().value("serve.quarantined") == 1
    faults.clear()
    fe.close()


def test_failed_flush_commits_no_ids(batches):
    """Satellite regression: a failed flush must not advance the id
    allocator or mutate ticket.ids — the next successful flush starts
    exactly where the failed one would have."""
    obs.reset()
    b = batches[0]
    svc = ResolveService(scheme="smp")
    cfg = ServingConfig(max_delay_ms=10.0, max_retries=0)
    fe = ServingFrontend(svc, cfg, start=False)
    doomed = fe.submit(list(b.names[:2]))
    faults.install(FaultPlan(site_hits={"rounds": frozenset(range(1, 50))}))
    fe.start()
    assert fe.drain(timeout=60.0)
    with pytest.raises(InjectedFault):
        doomed.wait(timeout=10.0)
    assert doomed.ids is None  # never committed
    assert fe._next_id == 0  # no id space burned
    faults.clear()
    ok = fe.submit(list(b.names[:2]))
    ok.wait(timeout=30.0)
    assert ok.ids == [0, 1]  # allocation starts where nothing happened
    fe.close()


def test_queue_depth_gauge_fresh_on_shed():
    """Satellite: the serve.queue.depth gauge is refreshed on the shed
    path, not only inside batch collection."""
    obs.reset()
    svc = ResolveService(scheme="smp")
    cfg = ServingConfig(max_queue=1, admission="reject", max_delay_ms=0.0)
    fe = ServingFrontend(svc, cfg, start=False)
    fe.submit(["a name"])
    with pytest.raises(AdmissionError):
        fe.submit(["b name"])
    reg = obs.get_registry()
    assert reg.gauge("serve.queue.depth").value == 1
    assert reg.value("serve.admission.shed") == 1
    fe.start()
    assert fe.drain(timeout=30.0)
    fe.close()


# ---------------------------------------------------------------------------
# Chaos smoke: seeded random plans compose with rollback + retry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_smoke_seeded(seed, batches, base_digest_smp):
    """A seeded random fault plan (site x hit chosen from the seed):
    ingest the stream, re-submitting any aborted batch after clearing
    the plan — rollback must make every abort invisible, so the final
    digest equals the clean run's regardless of seed."""
    import os

    seed = int(os.environ.get("REPRO_CHAOS_SEED", seed))
    svc = ResolveService(scheme="smp")
    aborted = []
    faults.install(FaultPlan.seeded(seed))
    try:
        for i, b in enumerate(batches):
            try:
                _ingest(svc, b)
            except InjectedFault:
                aborted.append(i)
                _ingest(svc, b)  # immediate retry on rolled-back state
    finally:
        faults.clear()
    assert state_digest(svc) == base_digest_smp, (
        f"seed {seed} (aborts at {aborted}) diverged"
    )
