"""Cross-host equivalence battery for sharded serving (ISSUE 9).

Three layers, cheapest first:

1. **Unit**: the FNV bucket partition is deterministic, exhaustive and
   disjoint; a bucket-partitioned LSH index whose per-shard answers are
   united reproduces the unsharded index exactly; a single-process
   :class:`~repro.stream.shard.ShardContext` degrades to the identity.
2. **Single-process multi-device**: ``tests/shard_worker.py`` under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` shards bin
   rows over N forced CPU devices; the state digest must equal the
   in-process single-device baseline — for N in {1, 2, 4}, smp and mmp,
   on the hepth stream and the evidence lattice, and under a permuted
   ingest schedule.
3. **Multi-process mesh**: N worker processes join a ``jax.distributed``
   CPU mesh (gloo collectives); every replica's digest must equal the
   single-host baseline, and the replicas must agree among themselves
   (``AGREE 1`` — a cross-process digest all-gather).  Gated by a probe
   run because not every jax build ships a CPU collectives client.

Digest equality is the ROADMAP item-1 correctness bar: bit-for-bit the
single-host fixpoint, not approximately it.
"""

from __future__ import annotations

import os
import socket
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset
from repro.launch.sharding import ShardSpec, bucket_shard
from repro.stream.digest import match_digest, state_digest
from repro.stream.index import LSHConfig, MinHashLSHIndex

WORKER = str(Path(__file__).parent / "shard_worker.py")
N_BATCHES = 3


def _run_worker(mode, scheme, *, devices=1, perm_seed=-1, env_extra=None,
                timeout=420):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")
    )
    env.update(env_extra or {})
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    return subprocess.run(
        [sys.executable, WORKER, mode, scheme, str(N_BATCHES), str(perm_seed)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def _parse(proc):
    assert proc.returncode == 0, (
        f"worker failed rc={proc.returncode}\n{proc.stdout}\n{proc.stderr}"
    )
    out = dict(
        line.split(None, 1) for line in proc.stdout.splitlines() if line
    )
    return out["DIGEST"], int(out.get("AGREE", "1"))


# -- layer 1: units ---------------------------------------------------------


def test_bucket_shard_partition_deterministic_exhaustive():
    rng = np.random.default_rng(0)
    keys = [
        (int(b), tuple(int(v) for v in rng.integers(0, 1 << 31, size=2)))
        for b in rng.integers(0, 64, size=512)
    ]
    for n in (1, 2, 4):
        owners = [bucket_shard(b, k, n) for b, k in keys]
        assert owners == [bucket_shard(b, k, n) for b, k in keys]
        assert all(0 <= o < n for o in owners)
        specs = [ShardSpec(n, i) for i in range(n)]
        for (b, k), o in zip(keys, owners):
            # exhaustive + disjoint: exactly one shard owns each bucket
            assert [s.owns(b, k) for s in specs].count(True) == 1
            assert specs[o].owns(b, k)
    # not trivially degenerate: at 4 shards all shards own something
    assert len({bucket_shard(b, k, 4) for b, k in keys}) == 4


def test_shard_spec_validation():
    with pytest.raises(ValueError):
        ShardSpec(n_shards=2, shard_id=2)
    with pytest.raises(ValueError):
        ShardSpec(n_shards=0, shard_id=0)
    with pytest.raises(ValueError):
        ShardSpec(n_shards=4, shard_id=-1)


def test_partitioned_index_union_equals_unsharded():
    """N bucket-partitioned index replicas, answers united, reproduce the
    unsharded index bit-for-bit (the in-process model of the cross-host
    probe merge)."""
    ds = make_dataset(SynthConfig.hepth(scale=0.02, seed=3))
    ids = list(range(len(ds.entities.names)))
    names = list(ds.entities.names)
    cfg = LSHConfig()
    base = MinHashLSHIndex(cfg)
    base.add(ids, names)
    for n in (2, 4):
        replicas = [
            MinHashLSHIndex(cfg, shard=ShardSpec(n, i)) for i in range(n)
        ]
        for rep in replicas:
            rep.add(ids, names)
        # the bucket maps are disjoint slices of the unsharded map
        for b in range(cfg.num_bands):
            seen: set = set()
            for rep in replicas:
                dup = seen & rep.buckets[b].keys()
                assert not dup
                seen |= rep.buckets[b].keys()
            assert seen == base.buckets[b].keys()
        probe = base.signatures(names[:17])
        expect = base.query(probe)
        union: set[int] = set()
        for rep in replicas:
            union |= rep.query(probe)
        assert union == expect


def test_single_process_context_is_identity():
    from repro.stream.shard import ShardContext, ShardCoordinator

    ctx = ShardContext.create()
    assert ctx.n_shards == 1 and ctx.shard_id == 0
    assert ctx.spec.owns(0, (1, 2))
    assert ctx.merger.union({3, 5}) == {3, 5}

    batches = arrival_stream(
        make_dataset(SynthConfig.hepth(scale=0.02, seed=3)), N_BATCHES
    )
    from repro.stream.service import ResolveService

    plain = ResolveService(scheme="smp", parallel=True)
    coord = ShardCoordinator(ctx, scheme="smp", parallel=True)
    for b in batches:
        plain.ingest(list(b.names), b.edges)
        coord.ingest(list(b.names), b.edges)
    assert coord.digest() == state_digest(plain)
    assert coord.digests_agree()


# -- layer 2: single-process multi-device mesh ------------------------------


@pytest.fixture(scope="module")
def hepth_baseline():
    """In-process single-device digests per (scheme, perm_seed)."""
    from repro.stream.service import ResolveService

    batches = arrival_stream(
        make_dataset(SynthConfig.hepth(scale=0.02, seed=3)), N_BATCHES
    )
    memo: dict = {}

    def get(scheme: str, perm_seed: int = -1) -> str:
        key = (scheme, perm_seed)
        if key not in memo:
            order = list(range(len(batches)))
            if perm_seed >= 0:
                order = [
                    int(i)
                    for i in np.random.default_rng(perm_seed).permutation(
                        len(batches)
                    )
                ]
            svc = ResolveService(scheme=scheme, parallel=True)
            for i in order:
                b = batches[i]
                svc.ingest(list(b.names), b.edges, ids=[int(x) for x in b.ids])
            memo[key] = state_digest(svc)
        return memo[key]

    return get


@pytest.fixture(scope="module")
def lattice_baseline():
    from repro.core.global_grounding import build_global_grounding
    from repro.core.mln import MLNMatcher
    from repro.core.parallel import run_parallel
    from repro.data.synthetic import make_lattice_cover

    memo: dict = {}

    def get(scheme: str) -> str:
        if scheme not in memo:
            packed, relations, weights = make_lattice_cover(depth=6, width=4)
            gg = (
                build_global_grounding(packed.pair_levels, relations, weights)
                if scheme == "mmp"
                else None
            )
            res = run_parallel(packed, MLNMatcher(weights), gg, scheme=scheme)
            memo[scheme] = match_digest(res.matches)
        return memo[scheme]

    return get


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
@pytest.mark.parametrize("devices", [1, 2, 4])
def test_multidevice_hepth_digest_equals_single_host(
    hepth_baseline, devices, scheme
):
    digest, agree = _parse(_run_worker("hepth", scheme, devices=devices))
    assert agree == 1
    assert digest == hepth_baseline(scheme)


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
@pytest.mark.parametrize("devices", [2, 4])
def test_multidevice_lattice_digest_equals_single_host(
    lattice_baseline, devices, scheme
):
    digest, _ = _parse(_run_worker("lattice", scheme, devices=devices))
    assert digest == lattice_baseline(scheme)


def test_multidevice_permuted_schedule_digest(hepth_baseline):
    digest, _ = _parse(_run_worker("hepth", "smp", devices=2, perm_seed=5))
    assert digest == hepth_baseline("smp", 5)
    # the digest is also schedule-invariant outright (ids preserved)
    assert digest == hepth_baseline("smp")


# -- layer 3: multi-process jax.distributed mesh ----------------------------


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_mesh(mode, scheme, n_procs, *, perm_seed=-1, timeout=420):
    """Spawn one worker per shard on a jax.distributed CPU mesh."""
    coord = f"127.0.0.1:{_free_port()}"
    procs = []
    for i in range(n_procs):
        procs.append(
            subprocess.Popen(
                [sys.executable, WORKER, mode, scheme, str(N_BATCHES),
                 str(perm_seed)],
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
                env={
                    **os.environ,
                    "JAX_PLATFORMS": "cpu",
                    "PYTHONPATH": str(Path(__file__).parent.parent / "src"),
                    "REPRO_SHARD_COORD": coord,
                    "REPRO_SHARD_N": str(n_procs),
                    "REPRO_SHARD_ID": str(i),
                },
            )
        )
    outs = []
    try:
        for p in procs:
            out, err = p.communicate(timeout=timeout)
            outs.append((p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return outs


_MESH_PROBE: dict[bool, str] = {}


def _mesh_or_skip():
    """Probe-and-skip: jax builds without a CPU collectives client (gloo)
    cannot run cross-process CPU meshes — the CI matrix includes one."""
    if not _MESH_PROBE:
        try:
            outs = _run_mesh("probe", "smp", 2, timeout=180)
            ok = all(rc == 0 for rc, _, _ in outs)
            detail = "" if ok else outs[0][2][-800:]
        except Exception as e:  # pragma: no cover - spawn trouble
            ok, detail = False, repr(e)
        _MESH_PROBE[True] = "" if ok else detail
    if _MESH_PROBE[True]:
        pytest.skip(
            "no multi-process CPU mesh on this jax build: "
            + _MESH_PROBE[True]
        )


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
@pytest.mark.parametrize("n_procs", [2, 4])
def test_mesh_hepth_digest_equals_single_host(hepth_baseline, n_procs, scheme):
    _mesh_or_skip()
    outs = _run_mesh("hepth", scheme, n_procs)
    expect = hepth_baseline(scheme)
    for rc, out, err in outs:
        assert rc == 0, f"shard failed rc={rc}\n{out}\n{err}"
        parsed = dict(ln.split(None, 1) for ln in out.splitlines() if ln)
        assert parsed["DIGEST"] == expect
        assert parsed["AGREE"] == "1"


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
def test_mesh_lattice_digest_equals_single_host(lattice_baseline, scheme):
    _mesh_or_skip()
    outs = _run_mesh("lattice", scheme, 2)
    for rc, out, err in outs:
        assert rc == 0, f"shard failed rc={rc}\n{out}\n{err}"
        parsed = dict(ln.split(None, 1) for ln in out.splitlines() if ln)
        assert parsed["DIGEST"] == lattice_baseline(scheme)


def test_mesh_permuted_schedule_digest(hepth_baseline):
    _mesh_or_skip()
    outs = _run_mesh("hepth", "smp", 2, perm_seed=5)
    for rc, out, err in outs:
        assert rc == 0, f"shard failed rc={rc}\n{out}\n{err}"
        parsed = dict(ln.split(None, 1) for ln in out.splitlines() if ln)
        assert parsed["DIGEST"] == hepth_baseline("smp", 5)
        assert parsed["AGREE"] == "1"
