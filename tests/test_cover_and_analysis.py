"""Covering (§4) properties + the loop-aware HLO analyzer."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import pipeline
from repro.core.cover import build_cover, is_total
from repro.core.metrics import true_pair_gids
from repro.data.synthetic import SynthConfig, make_dataset
from repro.launch import hlo_analysis as ha


# ---------------------------------------------------------------------------
# Covering
# ---------------------------------------------------------------------------


def test_cover_covers_all_entities(hepth_small):
    cover = build_cover(hepth_small.entities, hepth_small.relations)
    covered = set()
    for members in cover.full:
        covered.update(int(m) for m in members)
    assert covered == set(range(len(hepth_small.entities)))


def test_cover_total_wrt_relations(hepth_small):
    packed, gg, _ = pipeline.prepare(hepth_small.entities, hepth_small.relations)
    assert is_total(packed.cover, hepth_small.relations, gg.gids)


def test_blocking_recall(hepth_small):
    """Most ground-truth pairs are candidates in some neighborhood —
    the canopy blocking-recall property the paper inherits from [13]."""
    packed, gg, _ = pipeline.prepare(hepth_small.entities, hepth_small.relations)
    truth = hepth_small.entities.truth
    tp = true_pair_gids(truth)
    candidates = set(int(g) for g in gg.gids)
    hit = sum(1 for g in tp if int(g) in candidates)
    assert hit / max(len(tp), 1) > 0.8, (hit, len(tp))


def test_neighborhood_size_bounded(hepth_small):
    packed, _, _ = pipeline.prepare(
        hepth_small.entities, hepth_small.relations, k_max=32
    )
    for k, nb in packed.bins.items():
        assert nb.entity_mask.sum(axis=1).max() <= k <= 64


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------


def test_scan_flops_multiplied_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    n = 7
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((n, 64, 64), jnp.float32),
    ).compile()
    got = ha.analyze(c.as_text(), n_devices=1)["flops"]
    want = 2 * 64 * 64 * 64 * n
    assert want <= got <= want * 1.2, (got, want)
    # the built-in counter misses the loop (regression guard for WHY
    # we parse the HLO ourselves)
    builtin = c.cost_analysis().get("flops", 0.0)
    assert builtin < want


def test_nested_scan_flops():
    def f(x, ws):
        def outer(c, wpair):
            def inner(ci, w):
                return jnp.dot(ci, w), None
            c2, _ = jax.lax.scan(inner, c, wpair)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((3, 2, 32, 32), jnp.float32),
    ).compile()
    got = ha.analyze(c.as_text(), n_devices=1)["flops"]
    want = 2 * 32**3 * 6
    assert want <= got <= want * 1.5, (got, want)


def test_type_parsing():
    assert ha.type_elems_bytes("f32[4,8]{1,0}") == (32, 128)
    assert ha.type_elems_bytes("bf16[10]") == (10, 20)
    e, b = ha.type_elems_bytes("(f32[2,2]{1,0}, pred[], s32[3]{0})")
    assert e == 4 + 1 + 3 and b == 16 + 1 + 12


def test_instr_parse_tuple_with_index_comments():
    line = ("  %w = (s32[], f32[4,4]{1,0}, /*index=5*/bf16[2]{0}) "
            "while(%t), condition=%c, body=%b, backend_config={\"known_trip_count\":{\"n\":\"9\"}}")
    ins = ha._parse_instr(line)
    assert ins.opcode == "while"
    assert ins.result_bytes == 4 + 64 + 4
    assert "known_trip_count" in ins.rest


@given(st.integers(2, 6), st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_em_round_spmd_single_shard(k, seed):
    """The SPMD round function on a 1-device mesh reproduces the plain
    batched matcher (shard_map path correctness)."""
    from repro.core.mln import MLNMatcher, PAPER_LEARNED
    from repro.core.parallel import make_em_mesh, run_parallel
    from repro.core.driver import run_smp

    ds = make_dataset(SynthConfig.hepth(scale=0.01, seed=seed))
    packed, gg, _ = pipeline.prepare(ds.entities, ds.relations, k_max=8 * k)
    m = MLNMatcher(PAPER_LEARNED)
    seq = run_smp(packed, m)
    par = run_parallel(packed, m, gg, scheme="smp", mesh=make_em_mesh(1))
    assert seq.matches.as_set() == par.matches.as_set()
