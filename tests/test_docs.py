"""Docs can't rot: every module path the prose references must import.

README.md and docs/ARCHITECTURE.md name ``repro.*`` dotted paths and
repo file paths; if a refactor moves or renames one, this test fails CI
instead of leaving the documentation pointing at nothing.  CI also runs
``examples/quickstart.py`` itself (the bench-smoke job), so the
quickstart commands stay executable end to end.
"""

from __future__ import annotations

import importlib
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = [REPO / "README.md", REPO / "docs" / "ARCHITECTURE.md"]

# dotted references like ``repro.stream.index`` or
# ``repro.core.parallel.GroundingCache`` (trailing parts may be attrs)
DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z_0-9]*)+")
# backticked repo-relative file paths like `src/repro/core/cover.py`,
# `benchmarks/check_bench.py`, `docs/ARCHITECTURE.md` — at least one
# directory component, so bare names like `ops.py` aren't path-checked
FILEPATH = re.compile(r"`([A-Za-z_][\w.-]*(?:/[\w.*-]+)+\.(?:py|md|json|yml))`")


def _doc_text(path: Path) -> str:
    assert path.exists(), f"documented file missing: {path}"
    return path.read_text(encoding="utf-8")


def _import_dotted(ref: str) -> None:
    """Import the longest module prefix, then getattr the rest."""
    parts = ref.split(".")
    err = None
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError as e:
            err = e
            continue
        for attr in parts[cut:]:
            assert hasattr(obj, attr), f"{ref}: no attribute {attr!r}"
            obj = getattr(obj, attr)
        return
    raise AssertionError(f"{ref}: does not import ({err})")


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_dotted_module_references_import(doc):
    refs = sorted(set(DOTTED.findall(_doc_text(doc))))
    assert refs, f"{doc.name}: expected at least one repro.* reference"
    for ref in refs:
        _import_dotted(ref)


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_file_path_references_exist(doc):
    for ref in set(FILEPATH.findall(_doc_text(doc))):
        if "*" in ref:
            assert list(REPO.glob(ref)), f"{doc.name} glob matches nothing: {ref}"
        else:
            assert (REPO / ref).exists(), f"{doc.name} references missing {ref}"


def test_quickstart_paths_from_readme_exist():
    text = _doc_text(REPO / "README.md")
    assert "examples/quickstart.py" in text
    assert (REPO / "examples" / "quickstart.py").exists()
