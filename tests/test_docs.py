"""Docs can't rot: every module path the prose references must import.

README.md, docs/ARCHITECTURE.md, docs/SERVING.md and docs/SHARDING.md
name ``repro.*`` dotted paths and repo file paths; if a refactor moves
or renames one, this test fails CI instead of leaving the
documentation pointing at nothing.  CI also runs
``examples/quickstart.py`` itself (the bench-smoke job), so the
quickstart commands stay executable end to end.  SERVING.md and
SHARDING.md are additionally *operator* documents: every config knob
they name as ``Class.attr`` or call as ``Class(kwarg=...)`` must exist
on the corresponding class with exactly that name, so the tuning
guidance can't drift from the code.
"""

from __future__ import annotations

import dataclasses
import importlib
import inspect
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = [
    REPO / "README.md",
    REPO / "docs" / "ARCHITECTURE.md",
    REPO / "docs" / "SERVING.md",
    REPO / "docs" / "SHARDING.md",
]

# dotted references like ``repro.stream.index`` or
# ``repro.core.parallel.GroundingCache`` (trailing parts may be attrs)
DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z_0-9]*)+")
# backticked repo-relative file paths like `src/repro/core/cover.py`,
# `benchmarks/check_bench.py`, `docs/ARCHITECTURE.md` — at least one
# directory component, so bare names like `ops.py` aren't path-checked
FILEPATH = re.compile(r"`([A-Za-z_][\w.-]*(?:/[\w.*-]+)+\.(?:py|md|json|yml))`")


def _doc_text(path: Path) -> str:
    assert path.exists(), f"documented file missing: {path}"
    return path.read_text(encoding="utf-8")


def _import_dotted(ref: str) -> None:
    """Import the longest module prefix, then getattr the rest."""
    parts = ref.split(".")
    err = None
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError as e:
            err = e
            continue
        for attr in parts[cut:]:
            assert hasattr(obj, attr), f"{ref}: no attribute {attr!r}"
            obj = getattr(obj, attr)
        return
    raise AssertionError(f"{ref}: does not import ({err})")


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_dotted_module_references_import(doc):
    refs = sorted(set(DOTTED.findall(_doc_text(doc))))
    assert refs, f"{doc.name}: expected at least one repro.* reference"
    for ref in refs:
        _import_dotted(ref)


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_file_path_references_exist(doc):
    for ref in set(FILEPATH.findall(_doc_text(doc))):
        if "*" in ref:
            assert list(REPO.glob(ref)), f"{doc.name} glob matches nothing: {ref}"
        else:
            assert (REPO / ref).exists(), f"{doc.name} references missing {ref}"


def test_quickstart_paths_from_readme_exist():
    text = _doc_text(REPO / "README.md")
    assert "examples/quickstart.py" in text
    assert (REPO / "examples" / "quickstart.py").exists()


# ---------------------------------------------------------------------------
# SERVING.md is an operator document: every knob it names must exist
# ---------------------------------------------------------------------------

# backticked ``Class.attr`` references, e.g. `ServingConfig.max_batch`
CLASSATTR = re.compile(r"`([A-Z][A-Za-z0-9_]*)\.([a-z_][a-z0-9_]*)`")
# constructor-style mentions, e.g. ResolveService(gcache_capacity=...)
CALL = re.compile(r"\b([A-Z][A-Za-z0-9_]*)\(")


def _serving_namespace():
    import repro.stream as ns

    return ns


def _call_kwargs(text: str):
    """(ClassName, kwarg) pairs from call-style doc mentions, top-level
    kwargs only (nested constructor calls report to their own class)."""
    out = []
    for m in CALL.finditer(text):
        depth, end = 1, None
        for j in range(m.end(), len(text)):
            ch = text[j]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        if end is None:
            continue
        args = text[m.end():end]
        lvl, masked = 0, []
        for ch in args:
            if ch == "(":
                lvl += 1
            masked.append(ch if lvl == 0 else " ")
            if ch == ")":
                lvl -= 1
        for km in re.finditer(
            r"(?:^|,)\s*([a-z_][a-z0-9_]*)\s*=", "".join(masked)
        ):
            out.append((m.group(1), km.group(1)))
    return out


def _assert_knob(cls, cls_name: str, attr: str) -> None:
    if dataclasses.is_dataclass(cls):
        fields = {f.name for f in dataclasses.fields(cls)}
        assert attr in fields or hasattr(cls, attr), (
            f"SERVING.md names {cls_name}.{attr} but {cls_name} has no "
            f"such field (has: {sorted(fields)})"
        )
        return
    if hasattr(cls, attr):  # method / property / class attribute
        return
    params = inspect.signature(cls.__init__).parameters
    assert attr in params, (
        f"SERVING.md names {cls_name}.{attr} but {cls_name} has neither "
        f"an attribute nor an __init__ parameter of that name"
    )


def test_serving_doc_knobs_exist():
    """Every ``Class.attr`` and every ``Class(kwarg=...)`` SERVING.md
    names must exist on the real class — operator guidance that points
    at a renamed knob is worse than none."""
    text = _doc_text(REPO / "docs" / "SERVING.md")
    ns = _serving_namespace()
    checked = 0
    for cls_name, attr in CLASSATTR.findall(text):
        cls = getattr(ns, cls_name, None)
        if cls is None:  # not a serving-layer class (e.g. a paper term)
            continue
        _assert_knob(cls, cls_name, attr)
        checked += 1
    for cls_name, kwarg in _call_kwargs(text):
        cls = getattr(ns, cls_name, None)
        if cls is None:
            continue
        params = inspect.signature(cls.__init__).parameters
        assert kwarg in params, (
            f"SERVING.md calls {cls_name}({kwarg}=...) but __init__ has "
            f"no such parameter (has: {sorted(params)})"
        )
        checked += 1
    # the document must actually exercise the knob table: all four
    # ServingConfig knobs plus the constructor examples
    assert checked >= 8, f"only {checked} knob references found"


def test_sharding_doc_knobs_exist():
    """SHARDING.md is knob-checked the same way: every ``Class.attr``
    and ``Class(kwarg=...)`` it names must exist on the real sharding
    class — for classes taking ``**kwargs`` pass-through constructors
    (``ShardCoordinator``), any kwarg is accepted by construction."""
    import repro.launch.sharding
    import repro.stream
    import repro.stream.index
    import repro.stream.shard

    text = _doc_text(REPO / "docs" / "SHARDING.md")
    modules = (repro.stream.shard, repro.launch.sharding,
               repro.stream.index, repro.stream)

    def lookup(cls_name):
        for mod in modules:
            cls = getattr(mod, cls_name, None)
            if cls is not None:
                return cls
        return None

    checked = 0
    for cls_name, attr in CLASSATTR.findall(text):
        cls = lookup(cls_name)
        if cls is None:  # not a sharding-layer class (e.g. a paper term)
            continue
        _assert_knob(cls, cls_name, attr)
        checked += 1
    for cls_name, kwarg in _call_kwargs(text):
        cls = lookup(cls_name)
        if cls is None:
            continue
        params = inspect.signature(cls.__init__).parameters
        if any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
            checked += 1
            continue
        assert kwarg in params, (
            f"SHARDING.md calls {cls_name}({kwarg}=...) but __init__ has "
            f"no such parameter (has: {sorted(params)})"
        )
        checked += 1
    # the document must actually exercise the shard surface: the
    # ShardContext fields, the index hooks, and the constructor wiring
    assert checked >= 10, f"only {checked} knob references found"


def test_serving_config_knobs_all_documented():
    """The converse direction: every ``ServingConfig`` field must appear
    in SERVING.md — an undocumented knob is invisible to operators."""
    from repro.stream import ServingConfig

    text = _doc_text(REPO / "docs" / "SERVING.md")
    for f in dataclasses.fields(ServingConfig):
        assert f"ServingConfig.{f.name}" in text, (
            f"ServingConfig.{f.name} is not documented in SERVING.md"
        )


def test_service_config_knobs_all_documented():
    """Same for ``ServiceConfig``: the full ingest-side knob surface."""
    from repro.stream import ServiceConfig

    text = _doc_text(REPO / "docs" / "SERVING.md")
    for f in dataclasses.fields(ServiceConfig):
        assert f"ServiceConfig.{f.name}" in text, (
            f"ServiceConfig.{f.name} is not documented in SERVING.md"
        )


# ---------------------------------------------------------------------------
# Curated public surface: repro.__all__ / repro.stream.__all__
# ---------------------------------------------------------------------------

# `from repro import A, B` / `from repro.stream import C` in doc prose
# or fenced code blocks
FROM_IMPORT = re.compile(
    r"from\s+(repro(?:\.[a-z_][a-z0-9_.]*)?)\s+import\s+([A-Za-z_][A-Za-z_0-9, ]*)"
)


def test_public_api_exports_resolve():
    """Every name the curated surfaces promise actually resolves (the
    lazy PEP 562 table can't drift from the implementing modules)."""
    import repro
    import repro.stream

    for ns in (repro, repro.stream):
        assert ns.__all__ == sorted(ns.__all__), f"{ns.__name__}: unsorted"
        for name in ns.__all__:
            obj = getattr(ns, name)
            assert obj is not None, f"{ns.__name__}.{name}"
        assert set(ns.__all__) <= set(dir(ns))


def test_doc_imports_use_public_surface():
    """Every ``from repro[...] import X`` statement the docs show must
    go through a curated ``__all__`` — docs teaching private paths is
    how users end up pinned to implementation details."""
    import repro
    import repro.stream

    public = {
        "repro": set(repro.__all__),
        "repro.stream": set(repro.stream.__all__),
    }
    checked = 0
    for doc in DOCS:
        for mod, names in FROM_IMPORT.findall(_doc_text(doc)):
            if mod not in public:
                # deeper modules (repro.core.pipeline, ...) are the
                # library-internals tour, checked by the dotted-ref test
                continue
            for name in names.replace(",", " ").split():
                if isinstance(
                    getattr(importlib.import_module(mod), name, None),
                    type(importlib),
                ):
                    continue  # submodule import (from repro import obs)
                assert name in public[mod], (
                    f"{doc.name} imports {name} from {mod}, which is not "
                    f"in {mod}.__all__"
                )
                checked += 1
    # the README quickstart must actually exercise the curated surface
    assert checked >= 2, f"only {checked} public-surface imports in docs"
