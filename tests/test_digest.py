"""Property tests for the order-invariant state digest (ISSUE 9).

``stream.digest.state_digest`` is the equivalence oracle of the whole
fault-tolerance and sharding battery — crash recovery, replica
agreement, and the cross-host fixpoint checks all reduce to a digest
string equality.  That only works if the digest has exactly two
properties, probed here directly:

* **invariance**: ingesting the same corpus in a permuted order —
  batches reordered, entities shuffled within each batch, global ids
  preserved via ``ingest(..., ids=...)`` — lands on the identical
  digest (the fixpoint is schedule-invariant, Thm. 2/4, and the digest
  canonicalizes every unordered container on the way down);
* **sensitivity**: flipping any single cluster assignment — removing
  one member, moving a member between clusters, inventing a merge —
  changes the digest.  Without this, "digests agree" would be a
  vacuous check.

Everything is seeded; both fused schemes (smp/mmp) are covered.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset
from repro.stream.digest import match_digest, state_digest
from repro.stream.service import ResolveService

N_BATCHES = 3
PERM_SEEDS = (5, 11)


@pytest.fixture(scope="module")
def digest_corpus():
    return arrival_stream(
        make_dataset(SynthConfig.hepth(scale=0.02, seed=3)), N_BATCHES
    )


def _build(batches, scheme, perm_seed=None):
    """Ingest the corpus, optionally under a seeded schedule permutation
    (batch order and within-batch entity order; global ids preserved)."""
    svc = ResolveService(scheme=scheme, parallel=True)
    order = list(range(len(batches)))
    if perm_seed is not None:
        rng = np.random.default_rng(perm_seed)
        order = [int(i) for i in rng.permutation(len(batches))]
    for i in order:
        b = batches[i]
        ids = [int(x) for x in b.ids]
        names = list(b.names)
        if perm_seed is not None:
            p = np.random.default_rng(perm_seed + i).permutation(len(ids))
            ids = [ids[j] for j in p]
            names = [names[j] for j in p]
        svc.ingest(names, b.edges, ids=ids)
    return svc


@pytest.fixture(scope="module")
def services(digest_corpus):
    """Memoized (scheme, perm_seed) -> ingested service."""
    memo: dict = {}

    def get(scheme, perm_seed=None):
        key = (scheme, perm_seed)
        if key not in memo:
            memo[key] = _build(digest_corpus, scheme, perm_seed)
        return memo[key]

    return get


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
@pytest.mark.parametrize("perm_seed", PERM_SEEDS)
def test_digest_invariant_under_schedule_permutation(
    services, scheme, perm_seed
):
    base = services(scheme)
    perm = services(scheme, perm_seed)
    assert state_digest(perm) == state_digest(base)
    # and the resolved partitions are identical, not just the hashes
    want = sorted(tuple(sorted(m)) for m in base._members.values())
    got = sorted(tuple(sorted(m)) for m in perm._members.values())
    assert got == want


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
def test_digest_deterministic_across_rebuilds(digest_corpus, services, scheme):
    assert state_digest(services(scheme)) == state_digest(
        _build(digest_corpus, scheme)
    )


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
def test_digest_sensitive_to_any_single_cluster_flip(services, scheme):
    svc = services(scheme)
    orig = state_digest(svc)
    clusters = {r: set(m) for r, m in svc._members.items()}
    multi = {r for r, m in clusters.items() if len(m) >= 2}
    assert multi, "corpus produced no non-trivial clusters"
    roots = sorted(clusters)

    def flipped() -> str:
        d = state_digest(svc)
        assert state_digest(svc) == d  # digest itself has no hidden state
        return d

    seen = {orig}
    try:
        # remove each member of each cluster in turn
        for r in roots:
            for e in sorted(clusters[r]):
                svc._members[r] = clusters[r] - {e}
                d = flipped()
                assert d != orig, f"digest blind to removing {e} from {r}"
                seen.add(d)
                svc._members[r] = clusters[r]
        # move one member between every pair of clusters
        rs = sorted(multi)
        for ra in rs:
            for rb in roots:
                if rb == ra:
                    continue
                e = max(clusters[ra])
                svc._members[ra] = clusters[ra] - {e}
                svc._members[rb] = clusters[rb] | {e}
                assert flipped() != orig
                svc._members[ra] = clusters[ra]
                svc._members[rb] = clusters[rb]
        # invent a merge of an unclustered entity into a real cluster
        outside = set(range(len(svc.delta.names))) - set().union(*clusters.values())
        r = min(multi)
        for e in sorted(outside)[:8]:
            svc._members[r] = clusters[r] | {e}
            assert flipped() != orig
            svc._members[r] = clusters[r]
    finally:
        svc._members = {r: set(m) for r, m in clusters.items()}
    assert state_digest(svc) == orig  # restored exactly
    # distinct flips hash distinctly (no accidental collisions here)
    assert len(seen) == 1 + sum(len(clusters[r]) for r in roots)


def test_match_digest_order_invariant_and_sensitive():
    gids = np.array([7, 3, 11, 5], dtype=np.int64)
    d = match_digest(gids)
    assert match_digest(np.array([11, 5, 3, 7], dtype=np.int64)) == d
    assert match_digest(np.array([7, 3, 11], dtype=np.int64)) != d
    assert match_digest(np.array([7, 3, 11, 6], dtype=np.int64)) != d


# -- loadgen schedules: same seed, same offered load -------------------------


def test_loadgen_poisson_schedule_seeded():
    from benchmarks.loadgen import poisson_schedule

    a = poisson_schedule(np.random.default_rng(42), 50.0, 200)
    b = poisson_schedule(np.random.default_rng(42), 50.0, 200)
    assert np.array_equal(a, b)
    assert a.shape == (200,)
    assert np.all(np.diff(a) >= 0)  # cumulative arrival offsets
    c = poisson_schedule(np.random.default_rng(43), 50.0, 200)
    assert not np.array_equal(a, c)
    # offered-load sweep: every arrival at t0, regardless of seed
    assert np.array_equal(
        poisson_schedule(np.random.default_rng(0), float("inf"), 32),
        np.zeros(32),
    )


def test_loadgen_zipf_ids_seeded():
    from benchmarks.loadgen import zipf_ids

    a = zipf_ids(np.random.default_rng(7), 100, 500, 1.3)
    b = zipf_ids(np.random.default_rng(7), 100, 500, 1.3)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 100
    assert not np.array_equal(a, zipf_ids(np.random.default_rng(8), 100, 500, 1.3))
    # skew: the hottest id absorbs well over the uniform share
    hot = np.bincount(a).max()
    assert hot > 5 * (500 / 100)


def test_loadgen_reader_streams_reproducible():
    """The per-reader rngs are derived from cfg.seed (seed + 1000 + i):
    same config -> identical per-reader query key sequences, distinct
    readers -> distinct streams."""
    from benchmarks.loadgen import LoadgenConfig, zipf_ids

    cfg = LoadgenConfig(seed=3)
    streams = []
    for i in range(cfg.n_readers):
        r1 = np.random.default_rng(cfg.seed + 1000 + i)
        r2 = np.random.default_rng(cfg.seed + 1000 + i)
        s1 = [zipf_ids(r1, 50, cfg.reader_batch, cfg.zipf_a) for _ in range(4)]
        s2 = [zipf_ids(r2, 50, cfg.reader_batch, cfg.zipf_a) for _ in range(4)]
        assert all(np.array_equal(x, y) for x, y in zip(s1, s2))
        streams.append(np.concatenate(s1))
    assert not np.array_equal(streams[0], streams[1])
